"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The layer stack
is described by *segments*: ``(kinds, repeats)`` pairs, where ``kinds`` is a
tuple of layer-kind strings making up one repeating block. Each segment is
executed with ``jax.lax.scan`` over stacked per-layer parameters, which keeps
HLO size (and therefore compile time) independent of depth.

Layer kinds:
  attn        causal self-attention + MLP
  attn_local  local-window causal self-attention + MLP
  moe         causal self-attention + mixture-of-experts FFN
  attn_local_moe  local-window attention + MoE FFN (llama4-style iRoPE interleave)
  rglru       Griffin recurrent block (conv1d + RG-LRU) + MLP
  rwkv        RWKV-6 time-mix + RWKV channel-mix
  enc_attn    bidirectional self-attention + MLP (encoder)
  dec_attn    causal self-attention + cross-attention + MLP (enc-dec decoder)
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

Segment = Tuple[Tuple[str, ...], int]  # (block kinds, repeats)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    segments: Tuple[Segment, ...] = ()

    # --- attention ---
    attn_window: int = 0             # local-attention window (0 = n/a)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # fraction of head_dim that is rotated
    rope_style: str = "half"         # "half" (llama) | "interleaved" (chatglm)
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k

    # --- mlp ---
    mlp_type: str = "swiglu"         # swiglu | geglu | relu2 | gelu

    # --- moe ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_shared_expert: bool = False  # llama4: shared expert alongside routed
    moe_impl: str = "capacity"       # capacity (dropping, EP-sharded) | dense (exact)
    moe_parallelism: str = "ep"      # ep (experts->model axis, token a2a) |
                                     # fsdp (experts replicated at use,
                                     # FSDP-sharded storage; wins when
                                     # expert weights/layer << a2a volume)

    # --- ssm / recurrent ---
    lru_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4              # Griffin temporal conv width
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 32         # WKV chunk length (XLA path)

    # --- encoder / frontend (audio & vlm stubs) ---
    encoder_segments: Tuple[Segment, ...] = ()
    frontend: str = ""               # "" | "audio_frames" | "vision_patches"
    frontend_seq: int = 0            # frames / patches supplied by the stub
    # vlm: patch embeddings are prepended to token embeddings; audio: enc-dec

    # --- norm / embedding ---
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    emb_scale: bool = False          # multiply token emb by sqrt(d_model)

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- distribution policy ---
    fsdp: bool = False               # shard params over the data axis too
    sequence_parallel: bool = False  # shard residual seq over model axis
    remat: str = "none"              # none | full | dots
    scan_layers: bool = True
    train_microbatches: int = 1      # gradient-accumulation scan steps
    ce_chunks: int = 1               # sequence-chunked cross-entropy (big V)

    # --- attention implementation ---
    attn_impl: str = "xla"           # xla | pallas | pallas_interpret
    ssm_impl: str = "xla"            # xla | pallas | pallas_interpret

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.segments:
            object.__setattr__(self, "segments", ((("attn",), self.n_layers),))
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        total = sum(len(k) * r for k, r in self.segments)
        if self.encoder_segments:
            total_enc = sum(len(k) * r for k, r in self.encoder_segments)
        assert total == self.n_layers, (
            f"{self.name}: segments describe {total} layers, expected {self.n_layers}")

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 (TPU lane width, and makes
        the vocab axis shardable over 16-way model parallelism)."""
        return 128 * math.ceil(self.vocab_size / 128)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + per-layer)."""
        d, hd = self.d_model, self.head_dim
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_kind = {}
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp_mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        mlp = mlp_mult * d * self.d_ff
        moe = self.moe_experts * (3 * d * self.moe_d_ff) + d * self.moe_experts
        if self.moe_shared_expert:
            moe += 3 * d * self.d_ff
        per_kind["attn"] = attn + mlp
        per_kind["attn_local"] = attn + mlp
        per_kind["enc_attn"] = attn + mlp
        per_kind["dec_attn"] = 2 * attn + mlp
        per_kind["moe"] = attn + moe
        per_kind["attn_local_moe"] = attn + moe
        per_kind["rglru"] = (2 * d * self.lru_width + self.lru_width * d
                             + self.conv_width * self.lru_width
                             + 2 * self.lru_width + mlp)
        rk = self.rwkv_head_dim
        nh = d // rk
        per_kind["rwkv"] = (5 * d * d + d * d        # r,k,v,g,o
                            + 6 * 32 * d * 2         # ddlerp loras
                            + d * 64 * 2 + 2 * d     # decay lora, u
                            + 2 * d * self.d_ff + d * d)  # channel mix
        total = emb
        for kinds, reps in self.segments:
            for k in kinds:
                total += per_kind[k] * reps
        for kinds, reps in self.encoder_segments:
            for k in kinds:
                total += per_kind[k] * reps
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts instead of all)."""
        if self.moe_experts == 0:
            return self.param_count()
        full_moe = self.moe_experts * 3 * self.d_model * self.moe_d_ff
        active_moe = self.moe_top_k * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = sum(
            sum(1 for k in kinds if k in ("moe", "attn_local_moe")) * reps
            for kinds, reps in self.segments)
        return self.param_count() - n_moe_layers * (full_moe - active_moe)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not if skipped.

    ``long_500k`` needs sub-quadratic sequence mixing: it runs only for
    ssm/hybrid families (constant-size or windowed state); pure full-attention
    archs skip it (documented in DESIGN.md §Arch-applicability).
    """
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k skipped: full-attention arch (quadratic prefill, unbounded KV)"
    return True, ""
