"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]

48L, d_model=2048, 32H (GQA kv=4, explicit head_dim=128), expert d_ff=768,
vocab=151936. Every layer is MoE; no shared expert.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=6144, vocab_size=151936,
        segments=((("moe",), 48),),
        moe_experts=128, moe_top_k=8, moe_d_ff=768,
        moe_capacity_factor=1.25, moe_parallelism="fsdp",
        qk_norm=True, rope_theta=1000000.0,
        fsdp=True, sequence_parallel=True, remat="full", ce_chunks=8,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, segments=((("moe",), 2),),
        moe_experts=8, moe_top_k=2, moe_d_ff=32, fsdp=False)
