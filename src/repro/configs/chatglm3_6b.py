"""chatglm3-6b [dense] — 2d (partial, interleaved) RoPE, GQA kv=2.
[arXiv:2406.12793; hf]

28L, d_model=4096, 32H (GQA kv=2, head_dim 128), d_ff=13696, vocab=65024.
RoPE applied to half the head dim in interleaved (pairwise) style.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=65024,
        rope_fraction=0.5, rope_style="interleaved",
        fsdp=True, sequence_parallel=True, remat="full", ce_chunks=4,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, segments=(), fsdp=False)
