"""Paper payload models: ProGen (ProteinMPNN analogue, structure-conditioned
sequence model) and FoldScore (AlphaFold analogue, confidence scorer).

Both are built from the same transformer substrate as the assigned archs.
Sizes chosen so the full IMPRESS protocol runs end-to-end on the CPU test
host in seconds while remaining architecture-faithful payloads on TPU.
"""

from repro.configs.base import ModelConfig

AA_VOCAB = 32  # 20 amino acids + specials, padded


def progen_config() -> ModelConfig:
    return ModelConfig(
        name="progen-s", family="dense",
        n_layers=6, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab_size=AA_VOCAB,
        frontend="vision_patches",   # structure embeddings prepended as prefix
        frontend_seq=64,
        fsdp=False,
    )


def progen_reduced() -> ModelConfig:
    return progen_config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, segments=(), frontend_seq=8)


def foldscore_config() -> ModelConfig:
    return ModelConfig(
        name="foldscore-s", family="dense",
        n_layers=8, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=1024, vocab_size=AA_VOCAB,
        fsdp=False,
    )


def foldscore_reduced() -> ModelConfig:
    return foldscore_config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, segments=())


def foldscore_multimer_config() -> ModelConfig:
    """Heavier complex-scoring variant (the AlphaFold-Multimer analogue):
    staged binder protocols use it as the fold stage's second param set —
    a genuinely distinct model from the per-chain ``foldscore-s`` scorer,
    so the stage table exercises two configs, not just two inits."""
    return foldscore_config().replace(name="foldscore-m", n_layers=12,
                                      d_ff=1536)


def foldscore_multimer_reduced() -> ModelConfig:
    # segments re-cleared: the reduced base materializes a 2-layer plan in
    # __post_init__, which would contradict the deeper layer count
    return foldscore_reduced().replace(name="foldscore-m", n_layers=3,
                                       segments=())
