"""llava-next-34b [vlm] — anyres tiling frontend stubbed; Yi-34B backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

60L, d_model=7168, 56H (GQA kv=8, head_dim 128), d_ff=20480, vocab=64000.
Frontend: the vision tower + anyres tiling is a STUB — ``input_specs()``
supplies projected patch embeddings (B, 576, 7168), prepended to tokens.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab_size=64000,
        frontend="vision_patches", frontend_seq=576,
        rope_theta=5000000.0,
        fsdp=True, sequence_parallel=True, remat="full", ce_chunks=4,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, segments=(), frontend_seq=8,
        fsdp=False, remat="none")
