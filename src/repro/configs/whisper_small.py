"""whisper-small [audio] — enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]

12L(enc)+12L(dec), d_model=768, 12H (GQA kv=12), d_ff=3072, vocab=51865.
Frontend: the log-mel conv stem is a STUB — ``input_specs()`` supplies
precomputed frame embeddings (B, 1500, 768). Whisper's learned absolute
positions are replaced by RoPE (uniform substrate; noted in DESIGN.md).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=51865,
        segments=((("dec_attn",), 12),),
        encoder_segments=((("enc_attn",), 12),),
        frontend="audio_frames", frontend_seq=1500,
        norm_type="layernorm", mlp_type="gelu", tie_embeddings=True,
        fsdp=False, remat="full", ce_chunks=4, train_microbatches=4,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        segments=((("dec_attn",), 2),), encoder_segments=((("enc_attn",), 2),),
        frontend_seq=8)
