from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                SHAPES_BY_NAME, shape_applicable)
from repro.configs.registry import ARCH_IDS, get_config, get_reduced

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
           "shape_applicable", "ARCH_IDS", "get_config", "get_reduced"]
