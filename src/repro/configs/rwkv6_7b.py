"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

32L, d_model=4096 (64 heads x 64), d_ff=14336, vocab=65536. No RoPE.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab_size=65536,
        segments=((("rwkv",), 32),),
        rwkv_head_dim=64, rwkv_chunk=64,
        fsdp=True, remat="full", train_microbatches=8, ce_chunks=4,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, rwkv_head_dim=16,
        segments=((("rwkv",), 2),), fsdp=False)
