"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP.
[arXiv:2402.16819; unverified]

32L, d_model=6144, 48H (GQA kv=8, head_dim 128), d_ff=24576, vocab=256000.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab_size=256000,
        mlp_type="relu2", norm_type="layernorm",
        rope_theta=10000.0,
        fsdp=True, sequence_parallel=True, remat="full", ce_chunks=16,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, segments=(), fsdp=False)
