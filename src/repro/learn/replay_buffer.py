"""Fitness-weighted replay buffer of accepted designs.

The coordinator pushes every accepted design (the §V "HPC output becomes
training data" half of the bidirectional coupling) as a
(backbone, sequence, fitness, generator version) record. When full, the
lowest-fitness record is evicted, so the buffer concentrates on the best
designs seen so far. ``sample`` draws a fitness-weighted training batch in
the shape the ``finetune`` payload consumes.

The buffer is JSON-serializable (``state_dict``/``load_state_dict``) so it
rides along in the coordinator's checkpoint extra.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._items: List[dict] = []
        self._lock = threading.Lock()
        self.total_added = 0
        self.total_evicted = 0

    def add(self, backbone, sequence, fitness: float, gen_version: int = 0):
        item = {
            "backbone": np.asarray(backbone, np.float32),
            "sequence": np.asarray(sequence, np.int32),
            "fitness": float(fitness),
            "gen_version": int(gen_version),
        }
        with self._lock:
            self._items.append(item)
            self.total_added += 1
            if len(self._items) > self.capacity:
                worst = min(range(len(self._items)),
                            key=lambda i: self._items[i]["fitness"])
                self._items.pop(worst)
                self.total_evicted += 1

    def __len__(self):
        with self._lock:
            return len(self._items)

    def _weights(self, items: List[dict]) -> np.ndarray:
        """Sampling/training weights: fitness shifted positive so the worst
        retained design still has a small non-zero mass."""
        f = np.array([it["fitness"] for it in items], np.float32)
        w = f - f.min() + 1e-3
        return w

    def sample(self, k: int, rng: Optional[np.random.Generator] = None
               ) -> Optional[dict]:
        """Fitness-weighted batch of up to ``k`` designs (without
        replacement). Designs are grouped by (sequence length, backbone
        shape) and the largest group is sampled, so the batch stacks.
        Returns {"backbones", "sequences", "weights", "gen_versions"} or
        None when the buffer is empty."""
        rng = rng or np.random.default_rng(0)
        with self._lock:
            items = list(self._items)
        if not items:
            return None
        by_shape: Dict[tuple, List[dict]] = {}
        for it in items:
            key = (it["sequence"].shape, it["backbone"].shape)
            by_shape.setdefault(key, []).append(it)
        group = max(by_shape.values(), key=len)
        k = min(int(k), len(group))
        w = self._weights(group)
        idx = rng.choice(len(group), size=k, replace=False, p=w / w.sum())
        picked = [group[i] for i in idx]
        return {
            "backbones": np.stack([p["backbone"] for p in picked]),
            "sequences": np.stack([p["sequence"] for p in picked]),
            "weights": self._weights(picked),
            "gen_versions": np.array([p["gen_version"] for p in picked],
                                     np.int32),
        }

    def stats(self) -> dict:
        with self._lock:
            items = list(self._items)
        by_version: Dict[int, int] = {}
        for it in items:
            by_version[it["gen_version"]] = \
                by_version.get(it["gen_version"], 0) + 1
        return {
            "size": len(items),
            "capacity": self.capacity,
            "added": self.total_added,
            "evicted": self.total_evicted,
            "mean_fitness": (float(np.mean([i["fitness"] for i in items]))
                             if items else None),
            "by_gen_version": by_version,
        }

    # -- checkpoint/restart -------------------------------------------------

    def state_dict(self) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "added": self.total_added,
                "evicted": self.total_evicted,
                "items": [{
                    "backbone": it["backbone"].tolist(),
                    "sequence": it["sequence"].tolist(),
                    "fitness": it["fitness"],
                    "gen_version": it["gen_version"],
                } for it in self._items],
            }

    def load_state_dict(self, state: dict):
        with self._lock:
            self.capacity = int(state["capacity"])
            self.total_added = int(state["added"])
            self.total_evicted = int(state["evicted"])
            self._items = [{
                "backbone": np.asarray(it["backbone"], np.float32),
                "sequence": np.asarray(it["sequence"], np.int32),
                "fitness": float(it["fitness"]),
                "gen_version": int(it["gen_version"]),
            } for it in state["items"]]
