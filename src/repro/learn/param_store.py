"""Versioned, hot-swappable parameter store.

The generator's params live behind a ``ParamStore`` so model evolution can
swap them without touching in-flight work: every dispatch snapshots
``current()`` once — a (version, params) pair read under the lock — and
finishes on the version it started with, while ``publish`` installs the
evolved pytree as a new version atomically. Retired versions (beyond
``keep``) are announced to listeners so per-device param caches can drop
their copies by version instead of guessing at cache-key layouts.

Versions persist/restore through ``checkpoint.manager.CheckpointManager``
(the checkpoint *step* is the store version), so an evolved generator
survives a restart.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, List, Optional, Tuple


class ParamStore:
    def __init__(self, params: Any, *, version: int = 0, keep: int = 2):
        self._lock = threading.Lock()
        self._params: "OrderedDict[int, Any]" = OrderedDict([(version, params)])
        self._version = version
        self._max_version = version   # highest ever issued: version numbers
        #   are never reused, even after restoring an older checkpoint, so
        #   gen_version provenance stays unambiguous and retired-version
        #   tombstones downstream never match a live version
        self._listeners: List[Callable[[List[int]], None]] = []
        self.keep = max(1, int(keep))

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def current(self) -> Tuple[int, Any]:
        """Atomic (version, params) snapshot — the hot-swap read point. A
        dispatch calls this once and keeps the pair for its whole run."""
        with self._lock:
            return self._version, self._params[self._version]

    def get(self, version: int) -> Optional[Any]:
        with self._lock:
            return self._params.get(version)

    def versions(self) -> List[int]:
        with self._lock:
            return list(self._params)

    def publish(self, params: Any) -> int:
        """Install evolved ``params`` as the new current version; retire the
        oldest versions beyond ``keep`` and notify listeners (outside the
        lock) so they can evict per-device copies of retired versions."""
        with self._lock:
            v = self._max_version + 1
            self._params[v] = params
            self._version = v
            self._max_version = v
            retired = list(self._params)[:-self.keep]
            for r in retired:
                del self._params[r]
        if retired:
            for fn in list(self._listeners):
                fn(retired)
        return v

    def on_retire(self, fn: Callable[[List[int]], None]):
        """Register a callback invoked with the list of retired versions."""
        self._listeners.append(fn)

    # -- checkpoint/restart -------------------------------------------------

    def save(self, manager, *, block: bool = False) -> int:
        """Persist the current version through a ``CheckpointManager`` (the
        checkpoint step *is* the version)."""
        v, params = self.current()
        manager.save(v, params, extra={"param_store_version": v}, block=block)
        return v

    def restore(self, manager, step: Optional[int] = None) -> Optional[int]:
        """Restore the newest (or ``step``) persisted version, replacing the
        store's contents; returns the restored version or None if the
        manager has no checkpoint. Publishing continues past the highest
        version ever issued (never reusing a number, even when an older
        step was restored)."""
        _, template = self.current()
        state, _, got = manager.restore(template, step)
        if state is None:
            return None
        with self._lock:
            retired = [v for v in self._params if v != got]
            self._params = OrderedDict([(int(got), state)])
            self._version = int(got)
            self._max_version = max(self._max_version, int(got))
        if retired:
            for fn in list(self._listeners):
                fn(retired)
        return int(got)
