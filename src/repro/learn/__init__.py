# Model-evolution subsystem (paper §V): replay buffer of accepted designs
# (replay_buffer.py), versioned hot-swappable generator params
# (param_store.py), and the preemptible opportunistic trainer service
# (trainer.py). The finetune payload fn itself lives with the other device
# payloads in repro.core.payload (FinetunePayload).
from repro.learn.param_store import ParamStore
from repro.learn.replay_buffer import ReplayBuffer
from repro.learn.trainer import EvolutionConfig, TrainerService

__all__ = ["ParamStore", "ReplayBuffer", "EvolutionConfig", "TrainerService"]
