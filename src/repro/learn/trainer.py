"""Trainer service: opportunistic, preemptible model evolution.

Watches the executor from the coordinator's run loop (``tick`` per
iteration) and emits a low-priority **preemptible** ``finetune`` task only
when the middleware is idle — no queued design work and free devices — the
paper's "training run opportunistically on dynamically allocated idle
resources". A running trainer task yields its sub-mesh cooperatively the
moment design work queues (``AsyncExecutor.preempt_preemptible``); the
partial train state comes back in the task result and the service resubmits
the continuation on the next idle window, so training progress survives
preemption. The scheduler's aging guard (``TaskQueue.aging_s``) keeps a
parked trainer task from starving forever under a continuous design load.

Completed finetunes publish evolved params to the generator's
``ParamStore`` (done by the payload fn) and are recorded in ``history``
for the coordinator's quality-by-version report.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.pipeline import ResourceRequest, Task, TaskState
from repro.learn.replay_buffer import ReplayBuffer


@dataclass(frozen=True)
class EvolutionConfig:
    finetune_every: int = 0   # accepted designs between finetunes; 0 = off
    batch_size: int = 8       # designs per finetune batch (replay sample)
    min_designs: int = 4      # don't train before the buffer holds this many
    steps: int = 12           # train steps per finetune task
    priority: int = 100       # low urgency: design tasks sort first
    max_devices: int = 4      # cap on the trainer's data-parallel sub-mesh
    min_free_devices: int = 1  # idle threshold to emit a trainer task
    seed: int = 0


class TrainerService:
    def __init__(self, executor, buffer: ReplayBuffer, store,
                 cfg: EvolutionConfig, *, checkpoint=None):
        self.executor = executor
        self.buffer = buffer
        self.store = store
        self.cfg = cfg
        self.checkpoint = checkpoint   # optional CheckpointManager
        self._rng = np.random.default_rng(cfg.seed + 7)
        self._inflight: Optional[int] = None   # uid of the running task
        self._cur_payload: Optional[dict] = None
        self._resume: Optional[dict] = None    # resume state from preemption
        self._preempted_uid: Optional[int] = None  # uid the resume links to
        self._accepted_since = 0
        self.history: List[dict] = []          # one record per finetune
        self.submitted = 0
        self.completed = 0
        self.preempted = 0
        self.failed = 0
        self.steps_run = 0
        self.device_seconds = 0.0

    # -- coordinator-facing API -------------------------------------------

    def add_design(self, record: dict):
        """Feed one accepted design (a pipeline history row) into the
        replay buffer."""
        self.buffer.add(record["backbone"], record["sequence"],
                        record["fitness"], record.get("gen_version", 0))
        self._accepted_since += 1

    def owns(self, uid: int) -> bool:
        return uid == self._inflight

    def busy(self) -> bool:
        """True while a trainer task is in flight or a preempted finetune
        still has a continuation to run."""
        return self._inflight is not None or self._resume is not None

    def tick(self) -> Optional[Task]:
        """Submit a finetune task if evolution is due and the middleware is
        idle (no queued design work, free devices). Returns the submitted
        task, or None."""
        cfg = self.cfg
        if cfg.finetune_every <= 0 or self._inflight is not None:
            return None
        if self._resume is None:
            if self._accepted_since < cfg.finetune_every:
                return None
            if len(self.buffer) < max(1, cfg.min_designs):
                return None
        if len(self.executor.queue) > 0:      # design work queued: stand by
            return None
        if self.executor.allocator.n_free < cfg.min_free_devices:
            return None
        if self._resume is not None:
            payload = dict(self._cur_payload, resume=self._resume)
        else:
            batch = self.buffer.sample(cfg.batch_size, self._rng)
            if batch is None:
                return None
            payload = {"backbones": batch["backbones"],
                       "sequences": batch["sequences"],
                       "weights": batch["weights"],
                       "steps": cfg.steps}
            self._cur_payload = payload
            self._accepted_since = 0   # this batch consumes the trigger
        n = 1
        cap = min(self.executor.allocator.n_free, cfg.max_devices,
                  int(payload["sequences"].shape[0]))
        while n * 2 <= cap:
            n *= 2
        task = Task(kind="finetune", payload=payload, priority=cfg.priority,
                    preemptible=True, resources=ResourceRequest(n_devices=n))
        self._inflight = task.uid
        self.submitted += 1
        resuming = self._resume is not None
        self.executor.submit(task)
        if resuming and task.trace is not None:
            # span tracing on: link the continuation to the preempted task
            # it resumes, so the preempt/resume chain is walkable in traces
            task.trace["resumed_from"] = self._preempted_uid
        return task

    def on_complete(self, task: Task):
        """Route a drained trainer-task completion: stash resume state on
        preemption, record the finetune (and checkpoint the evolved params)
        on success."""
        self._inflight = None
        if task.state != TaskState.DONE:
            self.failed += 1
            self._resume = None
            self._cur_payload = None
            return
        r = task.result
        self.steps_run += int(r.get("steps_run", 0))
        self.device_seconds += float(r.get("elapsed_s", 0.0)) \
            * int(r.get("n_devices", 1))
        if r.get("preempted"):
            self.preempted += 1
            self._resume = r["resume"]
            self._preempted_uid = task.uid
            self.executor.telemetry.tracer.mark(task, "preempted")
            self.executor.telemetry.metrics.counter(
                "tasks.preempted", kind=task.kind).inc()
            return
        self.completed += 1
        self._resume = None
        self._cur_payload = None
        self.history.append({k: r[k] for k in (
            "base_version", "new_version", "loss_first", "loss_last",
            "mean_ll_first", "mean_ll_last", "n_designs", "steps_done")})
        if self.checkpoint is not None:
            self.store.save(self.checkpoint)

    def wait_idle(self, timeout: float = 60.0):
        """Drain the executor until no trainer task is in flight — for
        callers (benchmarks) that run finetunes outside a coordinator
        loop. Non-trainer completions are not expected here."""
        import time
        t0 = time.monotonic()
        while self.busy() and time.monotonic() - t0 < timeout:
            self.tick()
            task = self.executor.drain(timeout=0.1)
            if task is not None and self.owns(task.uid):
                self.on_complete(task)

    # -- reporting ---------------------------------------------------------

    def report(self, makespan: float, total_devices: int) -> dict:
        """Trainer stats for ``Coordinator.report()``. ``trainer_utilization``
        is finetune device-seconds over the pilot's device-seconds — how much
        of the run's idle capacity evolution soaked up."""
        wall = max(float(makespan), 1e-9)
        return {
            "enabled": self.cfg.finetune_every > 0,
            "param_version": self.store.version,
            "buffer": self.buffer.stats(),
            "submitted": self.submitted,
            "completed": self.completed,
            "preempted": self.preempted,
            "failed": self.failed,
            "steps_run": self.steps_run,
            "device_seconds": self.device_seconds,
            "trainer_utilization": (
                self.device_seconds / (max(1, total_devices) * wall)),
            "finetunes": list(self.history),
        }
