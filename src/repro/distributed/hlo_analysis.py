"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``cost_analysis`` gives FLOPs and HBM bytes of the per-device partitioned
program but is silent about collectives; we parse the compiled HLO text and
sum the *result-shape* bytes of every collective op (per-device shard sizes,
since the module is already partitioned). Global collective bytes = per-chip
bytes × chips, which makes the two forms of the roofline collective term
equal:  global/(chips·link_bw) == per_chip/link_bw.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}: ]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes per collective kind (result-shape convention)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    out["total"] = 0
    for m in _LINE_RE.finditer(hlo_text):
        result_type, op = m.group(1), m.group(2)
        b = _shape_bytes(result_type)
        out[op] += b
        out["total"] += b
    return out


# ---------------------------------------------------------------------------
# roofline model (TPU v5e constants; DESIGN.md §9)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link (per-chip effective)


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops: float = 0.0          # 6·N·D (train) or 2·N_active·D (serve)
    collectives: Dict[str, int] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_flops_ratio(self) -> float:
        """useful model FLOPs / compiled HLO FLOPs (global)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of peak FLOP/s at the bound, counting only
        useful model FLOPs: (model_flops/chips/peak) / t_bound."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.chips / PEAK_FLOPS) / self.t_bound

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
        }


def extract_cost(compiled) -> Dict[str, float]:
    """flops / bytes from compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return {"flops": flops, "bytes": byts, "raw_keys": len(ca)}
