"""Sharding rule engine: FSDP / TP / SP / EP, divisibility-aware.

Parameters are assigned PartitionSpecs by *path + shape* rules (t5x-style
logical axes, resolved against the active mesh). A tensor axis is sharded on
a mesh axis only when the dimension divides evenly; otherwise the rule falls
through to replication — this is how whisper's 12 heads or smollm's 15 heads
stay replicated on ``model`` while their FFNs carry the tensor parallelism.

Activation constraints inside model code go through :func:`constrain`, which
is a no-op unless a mesh context has been installed with
:func:`activation_sharding` — so the same model code runs in single-device
tests and 512-device dry-runs.
"""

from __future__ import annotations

import contextlib
import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

_ACTIVE: list = []  # stack of (mesh, cfg, mode)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, cfg, mode: str = "train"):
    """Install mesh+config so model-internal ``constrain`` calls take effect."""
    _ACTIVE.append((mesh, cfg, mode))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_mode() -> str:
    return _ACTIVE[-1][2] if _ACTIVE else "train"


def _axes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(dim: int, axes, mesh) -> Optional[Tuple[str, ...]]:
    """Return the mesh axes if ``dim`` divides their product, else None."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([_axes(mesh)[a] for a in axes]))
    return tuple(axes) if dim % size == 0 and dim >= size else None


def resolve_logical(logical, shape, mesh, cfg):
    """Map a tuple of logical names to a PartitionSpec for ``shape``."""
    spec = []
    for dim, name in zip(shape, logical):
        if name is None:
            spec.append(None)
            continue
        axes = {
            "batch": dp_axes(mesh),
            "expert_group": dp_axes(mesh),
            "expert_group_all": dp_axes(mesh) + ("model",),
            "data2d": ("data",),
            "seq": ("model",) if getattr(cfg, "sequence_parallel", False) else None,
            "vocab": ("model",),
            "heads": ("model",),
            "kv_heads": ("model",),
            "experts": ("model",),
            "ff": ("model",),
            "lru": ("model",),
            "fsdp": ("data",) if getattr(cfg, "fsdp", False) else None,
            "model": ("model",),
        }[name]
        fit = _fit(dim, axes, mesh)
        if fit is None and name == "expert_group_all":
            fit = _fit(dim, dp_axes(mesh), mesh)  # fall back to dp-only
        spec.append(fit)  # always a tuple (or None): P entries compare stably
    return P(*spec)


def constrain(x, logical):
    if not _ACTIVE:
        return x
    mesh, cfg = _ACTIVE[-1][:2]
    spec = resolve_logical(logical, x.shape, mesh, cfg)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def use_context_parallel(n_heads: int) -> bool:
    """Context parallelism for attention internals: when the head axis does
    not divide the ``model`` mesh axis (whisper 12, smollm 15, RG 10, llava
    56 vs 16-way TP), GSPMD would otherwise replicate the whole quadratic
    attention region 16×. Sharding the *query sequence* axis over ``model``
    instead splits it evenly (ring-attention-style CP, minus the ring)."""
    if not _ACTIVE:
        return False
    mesh = _ACTIVE[-1][0]
    m = _axes(mesh).get("model", 1)
    return n_heads % m != 0 and m > 1


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

# (path regex, logical axes per dim). First match wins. "F" = fsdp.
_PARAM_RULES = [
    (r"embedding/tok$", ("vocab", "fsdp")),
    (r"lm_head/w$", ("fsdp", "vocab")),
    (r"(attn|xattn)/wq$", ("fsdp", "heads", None)),
    (r"(attn|xattn)/w[kv]$", ("fsdp", "kv_heads", None)),
    (r"(attn|xattn)/wo$", ("heads", None, "fsdp")),
    (r"mlp/w[ig]$", ("fsdp", "ff")),
    (r"mlp/wo$", ("ff", "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w[ig]$", ("experts", "fsdp", None)),
    (r"moe/wo$", ("experts", None, "fsdp")),
    (r"moe/shared/w[ig]$", ("fsdp", "ff")),
    (r"moe/shared/wo$", ("ff", "fsdp")),
    (r"tm/w[rkvg]$", ("fsdp", "heads_flat")),
    (r"tm/wo$", ("heads_flat", "fsdp")),
    (r"tm/wc[k]$", ("fsdp", "ff")),
    (r"tm/wcv$", ("ff", "fsdp")),
    (r"tm/wcr$", ("fsdp", None)),
    (r"tm/(a_[rkvgw]|aw)$", ("fsdp", None)),
    (r"tm/(b_[rkvgw]|bw)$", (None, "fsdp")),
    (r"rec/(win|wgate)$", ("fsdp", "lru")),
    (r"rec/w[ri]$", (None, "lru")),
    (r"rec/conv_w$", (None, "lru")),
    (r"rec/wout$", ("lru", "fsdp")),
    (r"protein/.*", None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path_str: str, shape, mesh, cfg, mode: str = "train") -> P:
    """mode="train": FSDP storage (gather-at-use) for big archs.
    mode="serve": decode-time 2D tensor sharding — there is no optimizer
    state to co-shard, and per-step FSDP weight gathers dwarf the one-token
    compute (measured: 96 GB/step of expert-weight all-gathers on the 400B
    decode cell). Instead the would-be-FSDP dim shards over ``data`` as a
    second tensor axis; the resulting psums carry one token's activations."""
    ndim = len(shape)
    if mode == "serve" and re.search(r"moe/w[igo]$", path_str):
        # serve-time experts are stationary. Huge experts (ep mode, 400B
        # class): 2D (experts × data-on-f) so GSPMD has no weight-gather
        # option (it was choosing 96 GB/step of gathers over a 0.3 GB psum).
        # Small experts (fsdp mode): experts→model only; per-device stack is
        # a few GB and the token a2a is the only traffic.
        if getattr(cfg, "moe_parallelism", "ep") == "ep":
            logical = (None,) * (ndim - 3) + (
                ("experts", "data2d", None) if path_str.endswith("wo")
                else ("experts", None, "data2d"))
        else:
            logical = (None,) * (ndim - 3) + ("experts", None, None)
        return resolve_logical(logical, shape, mesh, cfg)
    # moe_parallelism="fsdp" (training): experts replicated at use
    # (all-gathering the small expert stack beats the top-k token a2a),
    # storage sharded over the data axis only.
    if (getattr(cfg, "moe_parallelism", "ep") == "fsdp"
            and re.search(r"moe/w[igo]$", path_str)):
        logical = (None,) * (ndim - 3) + (None, "fsdp", None)
        return resolve_logical(logical, shape, mesh, cfg)
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path_str):
            if logical is None:
                return P()
            logical = tuple(
                ("heads" if l == "heads_flat" else l) for l in logical)
            if mode == "serve":
                logical = tuple(("data2d" if l == "fsdp" else l)
                                for l in logical)
            # stacked segment params carry a leading repeats axis
            extra = ndim - len(logical)
            logical = (None,) * extra + logical
            return resolve_logical(logical, shape, mesh, cfg)
    return P()  # norms, biases, 1-D params: replicated


def param_spec_tree(shape_tree, mesh, cfg, mode: str = "train"):
    """PartitionSpec tree mirroring a params (shape) pytree."""
    def fn(path, leaf):
        return param_spec(_path_str(path), leaf.shape, mesh, cfg, mode)
    return jax.tree_util.tree_map_with_path(fn, shape_tree)


def sharding_tree(shape_tree, mesh, cfg, mode: str = "train"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_spec_tree(shape_tree, mesh, cfg, mode))


# ---------------------------------------------------------------------------
# cache / activation specs
# ---------------------------------------------------------------------------


def cache_spec(path_str: str, shape, mesh, cfg) -> P:
    """KV caches (R,B,L,KV,hd), ssm states (R,B,...). Shard batch over dp,
    kv-head axis over model when divisible."""
    ndim = len(shape)
    if path_str.endswith("pos"):
        return P()
    if re.search(r"/(k|v)$", path_str) and ndim >= 4:
        # (..., B, L, KV, hd): shard KV heads over model when divisible,
        # else fall back to sharding head_dim (keeps 32k-decode caches on
        # 16-way TP inside HBM even for kv=2..8 archs).
        logical = [None] * ndim
        logical[-4] = "batch"
        logical[-2] = "kv_heads"
        spec = resolve_logical(tuple(logical), shape, mesh, cfg)
        if spec[-2] is None:
            logical[-2] = None
            logical[-1] = "model"
            spec = resolve_logical(tuple(logical), shape, mesh, cfg)
        return spec
    if path_str.endswith("S") and ndim >= 3:  # rwkv state (R,B,H,K,K)
        logical = [None] * ndim
        logical[-4] = "batch"
        logical[-3] = "heads"
        return resolve_logical(tuple(logical), shape, mesh, cfg)
    if re.search(r"/(h|conv|shift_tm|shift_cm)$", path_str):
        logical = [None] * ndim
        # batch is the leading post-repeats axis
        logical[1 if ndim > 1 else 0] = "batch"
        if path_str.endswith(("h", "conv")):
            logical[-1] = "lru"
        return resolve_logical(tuple(logical), shape, mesh, cfg)
    return P()


def cache_spec_tree(shape_tree, mesh, cfg):
    def fn(path, leaf):
        return cache_spec(_path_str(path), leaf.shape, mesh, cfg)
    return jax.tree_util.tree_map_with_path(fn, shape_tree)


def cache_sharding_tree(shape_tree, mesh, cfg):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_spec_tree(shape_tree, mesh, cfg))


def batch_spec(mesh, cfg=None) -> P:
    return P(dp_axes(mesh))


def tokens_sharding(mesh, shape):
    """(B, S) int tokens: shard batch over dp axes when divisible."""
    dp = dp_axes(mesh)
    size = int(np.prod([_axes(mesh)[a] for a in dp]))
    if shape[0] % size == 0:
        return NamedSharding(mesh, P(dp))
    return NamedSharding(mesh, P())
