"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's built-in ``cost_analysis`` counts ``while`` bodies exactly once, which
under-counts scanned-layer programs by ~L×. This walker parses the compiled
module text, builds the computation call graph (while/call/fusion), and
multiplies loop-body costs by the ``known_trip_count`` annotation XLA
attaches to scan-derived loops.

Conventions (documented in EXPERIMENTS.md §Roofline):
  flops     dot = 2·|result|·|contracted dims|; elementwise/transcendental =
            |result|; reduce = |operand|; data movement = 0.
  bytes     per instruction: operand + result bytes, with slicing ops
            counted at touched-bytes (2·|result|), fusion internals free —
            an HBM-traffic estimate in the spirit of XLA's "bytes accessed".
  coll      collective result bytes by op kind (per-device shard sizes,
            post-partitioning).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

TRANSCENDENTAL = {"exponential", "exponential-minus-one", "log", "log-plus-one",
                  "tanh", "sqrt", "rsqrt", "power", "divide", "sine", "cosine",
                  "logistic", "atan2", "cbrt", "erf", "remainder"}
ELEMENTWISE = {"add", "subtract", "multiply", "maximum", "minimum", "and", "or",
               "xor", "not", "negate", "abs", "sign", "floor", "ceil",
               "round-nearest-afz", "round-nearest-even", "compare", "select",
               "clamp", "shift-left", "shift-right-logical",
               "shift-right-arithmetic", "popcnt", "clz", "is-finite",
               "stochastic-convert"}
DATA_MOVE = {"copy", "transpose", "reshape", "broadcast", "slice",
             "dynamic-slice", "dynamic-update-slice", "concatenate", "gather",
             "scatter", "convert", "bitcast", "bitcast-convert", "tuple",
             "get-tuple-element", "parameter", "constant", "iota", "reverse",
             "pad", "copy-start", "copy-done", "optimization-barrier",
             "rng-bit-generator", "partition-id", "replica-id", "after-all",
             "add-dependency", "domain"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast",
               "all-reduce-start", "all-gather-start",
               "collective-permute-start"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.coll.items()})

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str

    @property
    def op_name(self) -> str:
        m = re.search(r'op_name="([^"]*)"', self.attrs)
        return m.group(1) if m else ""


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?|[a-z0-9]+\[\]|token\[\])"
    r"\s+([a-z][\w\-]*)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str):
    """-> (computations: name -> [Instr], entry_name)"""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
        else:
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, tstr, opcode, rest = m.groups()
                # split args part (up to matching paren) from attrs
                depth, i = 1, 0
                while i < len(rest) and depth:
                    if rest[i] == "(":
                        depth += 1
                    elif rest[i] == ")":
                        depth -= 1
                    i += 1
                args, attrs = rest[:i - 1], rest[i:]
                ops = _OPERAND_RE.findall(args)
                comps[cur].append(Instr(name, tstr, opcode, ops, attrs))
    return comps, entry


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CALLS_RE = re.compile(r'calls=%?([\w.\-]+)')
_BODY_RE = re.compile(r'body=%?([\w.\-]+)')
_COND_RE = re.compile(r'condition=%?([\w.\-]+)')
_TO_RE = re.compile(r'to_apply=%?([\w.\-]+)')
_LHS_C_RE = re.compile(r'lhs_contracting_dims=\{([0-9,]*)\}')


def analyze(text: str, tag_re: Optional[str] = None):
    """Walk the module. Returns Cost, or (Cost, tagged_Cost) when ``tag_re``
    is given — the tagged cost sums only instructions whose op_name metadata
    matches (e.g. r"flash|_sdpa" to isolate attention-internal traffic)."""
    comps, entry = parse_hlo(text)
    tag = re.compile(tag_re) if tag_re else None
    types: Dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            types[ins.name] = ins.type_str

    memo: Dict[str, Cost] = {}

    # For fusions: a parameter consumed only by (dynamic-)slice ops inside
    # the fused computation touches only the sliced bytes (scanned stacked
    # params are the canonical case — without this, loop carries count L×).
    param_eff: Dict[str, Dict[int, float]] = {}

    def _param_effective(comp: str) -> Dict[int, float]:
        if comp in param_eff:
            return param_eff[comp]
        instrs = comps.get(comp, [])
        # parameter index: parameter ops appear in index order in HLO text
        pidx: Dict[str, int] = {}
        order = [ins for ins in instrs if ins.opcode == "parameter"]
        for i, ins in enumerate(order):
            pidx[ins.name] = i
        eff: Dict[int, float] = {}
        uses: Dict[str, list] = {}
        for ins in instrs:
            for o in ins.operands:
                if o in pidx:
                    uses.setdefault(o, []).append(ins)
        for pname, i in pidx.items():
            us = uses.get(pname, [])
            if us and all(u.opcode in ("dynamic-slice", "slice") for u in us):
                eff[i] = float(sum(_shape_elems_bytes(u.type_str)[1]
                                   for u in us))
        param_eff[comp] = eff
        return eff

    def op_bytes(ins: Instr) -> float:
        _, rb = _shape_elems_bytes(ins.type_str)
        if ins.opcode in ("slice", "dynamic-slice", "gather"):
            return 2.0 * rb
        if ins.opcode in ("dynamic-update-slice", "scatter"):
            upd = (_shape_elems_bytes(types.get(ins.operands[1], ""))[1]
                   if len(ins.operands) > 1 else rb)
            return 2.0 * upd
        if ins.opcode in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast", "reshape",
                          "after-all", "optimization-barrier"):
            return 0.0
        total = float(rb)
        for o in ins.operands:
            total += _shape_elems_bytes(types.get(o, ""))[1]
        return total

    def comp_cost(name: str):
        if name in memo:
            return memo[name]
        total = Cost()
        tagged = Cost()
        memo[name] = (total, tagged)  # guards cycles
        for ins in comps.get(name, []):
            oc = ins.opcode
            relems, rbytes = _shape_elems_bytes(ins.type_str)
            hit = bool(tag and tag.search(ins.op_name))

            def acc(c: Cost, h=None):
                total.__iadd__(c)
                if (hit if h is None else h):
                    tagged.__iadd__(c)

            if oc == "while":
                m = _TRIP_RE.search(ins.attrs)
                trips = int(m.group(1)) if m else 1
                body = _BODY_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                for mm in (body, cond):
                    if mm:
                        st, sg = comp_cost(mm.group(1))
                        total.__iadd__(st.scaled(trips))
                        tagged.__iadd__(sg.scaled(trips))
                continue
            if oc == "fusion":
                m = _CALLS_RE.search(ins.attrs)
                byts = float(_shape_elems_bytes(ins.type_str)[1])
                if m:
                    st, sg = comp_cost(m.group(1))
                    total.__iadd__(Cost(st.flops, 0.0, dict(st.coll)))
                    tagged.__iadd__(Cost(sg.flops, 0.0, dict(sg.coll)))
                    eff = _param_effective(m.group(1))
                    for i, o in enumerate(ins.operands):
                        byts += eff.get(
                            i, _shape_elems_bytes(types.get(o, ""))[1])
                else:
                    byts = op_bytes(ins)
                acc(Cost(0.0, byts, {}))
                continue
            if oc in ("call", "custom-call", "async-start"):
                m = _TO_RE.search(ins.attrs) or _CALLS_RE.search(ins.attrs)
                if m:
                    st, sg = comp_cost(m.group(1))
                    total.__iadd__(st)
                    tagged.__iadd__(sg)
                continue
            if oc in ("reduce", "reduce-window"):
                opb = sum(_shape_elems_bytes(types.get(o, ""))[0]
                          for o in ins.operands[:max(1, len(ins.operands) // 2)])
                acc(Cost(float(opb), op_bytes(ins), {}))
                continue
            if oc == "dot":
                lhs_t = types.get(ins.operands[0], "")
                mdims = _LHS_C_RE.search(ins.attrs)
                contracted = 1
                if mdims and lhs_t:
                    dims_m = _SHAPE_RE.search(lhs_t)
                    if dims_m:
                        lhs_dims = [int(d) for d in dims_m.group(2).split(",")
                                    if d]
                        for ci in mdims.group(1).split(","):
                            if ci:
                                contracted *= lhs_dims[int(ci)]
                acc(Cost(2.0 * relems * contracted, op_bytes(ins), {}))
                continue
            if oc == "convolution":
                acc(Cost(2.0 * relems, op_bytes(ins), {}))
                continue
            if oc in COLLECTIVES or oc.rstrip("-done") in COLLECTIVES:
                kind = oc.replace("-start", "").replace("-done", "")
                if oc.endswith("-done"):
                    continue
                acc(Cost(0.0, 0.0, {kind: float(rbytes)}))
                continue
            if oc in TRANSCENDENTAL or oc in ELEMENTWISE:
                acc(Cost(float(relems), op_bytes(ins), {}))
                continue
            acc(Cost(0.0, op_bytes(ins), {}))
        memo[name] = (total, tagged)
        return total, tagged

    # fused computations are only counted via their callers; start from entry
    total, tagged = comp_cost(entry)
    return (total, tagged) if tag_re else total
